"""Dense decoder-only transformer (llama/granite/stablelm/deepseek/danube,
and the chameleon VLM backbone — early-fusion VQ tokens are ordinary ids).

Layer parameters are STACKED on a leading ``layers`` axis and the forward
pass is a single ``lax.scan`` over that axis: HLO size stays O(1) in depth,
which keeps 512-device lowering of 30–48 layer models tractable.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_block(key, cfg):
    k1, k2 = jax.random.split(key)
    pd = jnp.dtype(cfg.param_dtype)
    if cfg.moe is not None:
        from repro.models import moe
        mlp_p = moe.init_moe_mlp(k2, cfg)
    else:
        mlp_p = L.init_mlp(k2, cfg)
    return {
        "attn_norm": jnp.zeros((cfg.d_model,), pd),
        "attn": L.init_attention(k1, cfg),
        "mlp_norm": jnp.zeros((cfg.d_model,), pd),
        "mlp": mlp_p,
    }


def init(key, cfg):
    ks = jax.random.split(key, 3)
    pd = jnp.dtype(cfg.param_dtype)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    p = {
        "embed": L.dense_init(ks[1], (cfg.vocab_size, cfg.d_model), pd,
                              scale=1.0),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), pd),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.dense_init(ks[2], (cfg.d_model, cfg.vocab_size), pd)
    return p


def _block_apply(bp, cfg, x, positions, window, cache, cache_index):
    h = L.rms_norm(x, bp["attn_norm"], cfg.norm_eps)
    a, new_cache = L.attention_block(
        bp["attn"], cfg, h, positions, window=window,
        cache=cache, cache_index=cache_index)
    x = x + a
    h = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if cfg.moe is not None:
        from repro.models import moe
        from repro.sharding.context import get_mesh
        mesh = get_mesh()
        if mesh is not None:
            y, aux = moe.moe_block_distributed(bp["mlp"], cfg, h, mesh)
        else:
            y, aux = moe.moe_block(bp["mlp"], cfg, h)
    else:
        y = L.mlp_block(bp["mlp"], cfg, h)
    x = x + y
    return x, new_cache, aux


def forward(params, cfg, tokens, *, positions=None, caches=None,
            cache_index=None, embeddings: Optional[jnp.ndarray] = None):
    """tokens (B, S) int32 -> logits (B, S, V).

    ``caches``: stacked {'k': (L,B,C,K,hd), 'v': ...} or None.
    ``embeddings``: optional (B, S, d) — bypasses the embed table (modality
    frontends feed precomputed embeddings here).
    Returns (logits, new_caches, aux_loss).
    """
    dt = jnp.dtype(cfg.dtype)
    if embeddings is None:
        x = params["embed"][tokens].astype(dt)
    else:
        x = embeddings.astype(dt)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :] + (
            0 if cache_index is None else cache_index)
        positions = jnp.broadcast_to(positions, (B, S))
    window = cfg.sliding_window

    def block_fn(bp, x, cache):
        return _block_apply(bp, cfg, x, positions, window, cache, cache_index)

    if cfg.remat:
        block_fn = L.checkpoint_fn(cfg)(block_fn)

    if cfg.unroll_layers:
        new_list = []
        aux_total = jnp.float32(0.0)
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            cache = None if caches is None else jax.tree.map(
                lambda a: a[i], caches)
            x, nc, a = block_fn(bp, x, cache)
            aux_total = aux_total + a
            new_list.append(nc)
        new_caches = None if caches is None else jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_list)
    elif caches is None:
        def body_nc(carry, bp):
            x, aux = carry
            y, _, a = block_fn(bp, x, None)
            return (y, aux + a), None
        (x, aux_total), _ = jax.lax.scan(body_nc, (x, jnp.float32(0.0)),
                                         params["blocks"])
        new_caches = None
    else:
        def body_c(carry, inp):
            x, aux = carry
            bp, cache = inp
            y, new_cache, a = block_fn(bp, x, cache)
            return (y, aux + a), new_cache
        (x, aux_total), new_caches = jax.lax.scan(
            body_c, (x, jnp.float32(0.0)), (params["blocks"], caches))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w_out = (params["embed"].T if cfg.tie_embeddings
             else params["unembed"]).astype(dt)
    logits = x @ w_out
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.logit_softcap).astype(dt)
    return logits, new_caches, aux_total


def init_cache(cfg, batch: int, seq_len: int):
    one = L.init_kv_cache(cfg, batch, seq_len, window=cfg.sliding_window)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape),
        one)
