"""xLSTM [arXiv:2405.04517]: stack of mLSTM (matrix-memory, parallelizable)
and sLSTM (scalar-memory, hidden-to-hidden recurrent) blocks.

mLSTM cell (per head, stabilized, log-sigmoid forget):
    m_t = max(lf_t + m_{t-1}, li_t)
    C_t = e^{lf_t + m_{t-1} - m_t} C_{t-1} + e^{li_t - m_t} k_t v_t^T
    n_t = e^{lf_t + m_{t-1} - m_t} n_{t-1} + e^{li_t - m_t} k_t
    h_t = (q_t C_t) / max(|q_t · n_t|, e^{-m_t})

Train/prefill uses the CHUNKWISE form (intra-chunk quadratic + inter-chunk
state carry, O(T·L) work, O(L^2) live memory) — validated against the
step-by-step recurrence (`mlstm_recurrent`, also the decode path) in tests.
sLSTM has true hidden-to-hidden mixing and is inherently sequential:
`lax.scan` over time — that is xLSTM's stated tradeoff, not a shortcut.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.rglru import conv1d_apply, init_conv1d


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------


def mlstm_recurrent(q, k, v, li, lf, state=None):
    """Step-by-step oracle + decode path.

    q,k,v: (B, H, T, hd); li, lf: (B, H, T) log input/forget gates.
    state: (C (B,H,hd,hd), n (B,H,hd), m (B,H)) or None.
    Returns (h (B,H,T,hd), state).
    """
    B, H, T, hd = q.shape
    q = q.astype(jnp.float32) / math.sqrt(hd)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, lit, lft = inp
        m_new = jnp.maximum(lf_shift := lft + m, lit)
        m_new = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        a = jnp.exp(lf_shift - m_new)          # (B, H)
        bcoef = jnp.exp(lit - m_new)
        C = a[..., None, None] * C + bcoef[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = a[..., None] * n + bcoef[..., None] * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), li.transpose(2, 0, 1),
          lf.transpose(2, 0, 1))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3), (C, n, m)


def mlstm_chunked(q, k, v, li, lf, state=None, chunk: int = 256,
                  unroll: bool = False):
    """Chunkwise-parallel mLSTM. Same signature as ``mlstm_recurrent``.

    ``unroll``: python-loop over chunks instead of ``lax.scan`` (used by
    the cost-analysis probes — scan bodies are counted once by XLA)."""
    B, H, T, hd = q.shape
    if T % chunk:
        pad = chunk - T % chunk
        zf = lambda a, fill=0.0: jnp.pad(
            a, [(0, 0)] * (a.ndim - 1) + [(0, pad)] if a.ndim == 3 else
            [(0, 0), (0, 0), (0, pad), (0, 0)], constant_values=fill)
        q, k, v = zf(q), zf(k), zf(v)
        li = zf(li, -1e30)   # padded steps: no input
        lf = zf(lf, 0.0)     # no decay
        Tp = T + pad
    else:
        Tp = T
    nc = Tp // chunk
    q = q.reshape(B, H, nc, chunk, hd).astype(jnp.float32) / math.sqrt(hd)
    k = k.reshape(B, H, nc, chunk, hd).astype(jnp.float32)
    v = v.reshape(B, H, nc, chunk, hd).astype(jnp.float32)
    li = li.reshape(B, H, nc, chunk).astype(jnp.float32)
    lf = lf.reshape(B, H, nc, chunk).astype(jnp.float32)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_step(carry, inp):
        C, n, m = carry                       # m may be -inf (empty state)
        qc, kc, vc, lic, lfc = inp            # (B,H,L,hd) / (B,H,L)
        b = jnp.cumsum(lfc, axis=-1)          # inclusive decay sums
        g = lic - b                           # (B,H,L)
        gmax = jax.lax.cummax(g, axis=2)
        m_inter = m[..., None] + b            # (B,H,L)
        m_t = jnp.maximum(m_inter, b + gmax)
        m_t = jnp.where(jnp.isneginf(m_t), 0.0, m_t)

        # intra-chunk: D_ts = exp(b_t - b_s + li_s - m_t), s <= t
        logD = (b[..., :, None] - b[..., None, :] + lic[..., None, :]
                - m_t[..., :, None])
        mask = jnp.tril(jnp.ones((qc.shape[2], qc.shape[2]), bool))
        D = jnp.where(mask, jnp.exp(logD), 0.0)
        S = jnp.einsum("bhtk,bhsk->bhts", qc, kc) * D
        num = jnp.einsum("bhts,bhsv->bhtv", S, vc)
        den = jnp.sum(S, axis=-1)

        # inter-chunk: carry state contribution
        w_inter = jnp.exp(m_inter - m_t)      # (B,H,L); exp(-inf)=0 ok
        w_inter = jnp.where(jnp.isneginf(m_inter), 0.0, w_inter)
        num = num + w_inter[..., None] * jnp.einsum("bhtk,bhkv->bhtv", qc, C)
        den = den + w_inter * jnp.einsum("bhtk,bhk->bht", qc, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # new carry
        Bl = b[..., -1]                       # (B,H)
        cand = Bl[..., None] - b + lic        # (B,H,L)
        m_new = jnp.maximum(m + Bl, jnp.max(cand, axis=-1))
        m_new = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        wc = jnp.exp(m + Bl - m_new)
        wc = jnp.where(jnp.isneginf(m + Bl), 0.0, wc)
        ws = jnp.exp(cand - m_new[..., None])  # (B,H,L)
        C_new = wc[..., None, None] * C + jnp.einsum(
            "bhs,bhsk,bhsv->bhkv", ws, kc, vc)
        n_new = wc[..., None] * n + jnp.einsum("bhs,bhsk->bhk", ws, kc)
        return (C_new, n_new, m_new), h

    xs = (q.transpose(2, 0, 1, 3, 4), k.transpose(2, 0, 1, 3, 4),
          v.transpose(2, 0, 1, 3, 4), li.transpose(2, 0, 1, 3),
          lf.transpose(2, 0, 1, 3))
    if unroll:
        carry = (C0, n0, m0)
        hs_list = []
        for i in range(nc):
            carry, hh = chunk_step(carry, jax.tree.map(lambda a: a[i], xs))
            hs_list.append(hh)
        (C, n, m), hs = carry, jnp.stack(hs_list)
    else:
        (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, Tp, hd)[:, :, :T]
    return h, (C, n, m)


# ---------------------------------------------------------------------------
# sLSTM cell (sequential; hidden-to-hidden recurrence)
# ---------------------------------------------------------------------------


def slstm_apply(p, x, state=None):
    """x: (B, T, d); heads H with per-head recurrent mixing R (H, hd, hd).

    state: (c, n, m, h) each (B, H, hd) / (B, H) for m. Returns (y, state).
    """
    B, T, d = x.shape
    H, hd, _ = p["r_z"].shape
    xf = x.astype(jnp.float32)
    # input contributions for all gates, all steps: (B, T, 4, H, hd)
    wx = jnp.einsum("btd,dghk->btghk", xf,
                    p["w"].astype(jnp.float32))  # gates g: z,i,f,o
    if state is None:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H, hd), -jnp.inf, jnp.float32)
        h0 = jnp.zeros((B, H, hd), jnp.float32)
    else:
        c0, n0, m0, h0 = state

    R = tuple(p[f"r_{g}"].astype(jnp.float32) for g in "zifo")
    bias = p["b"].astype(jnp.float32)          # (4, H, hd)

    def step(carry, wxt):
        c, n, m, h = carry
        rec = [jnp.einsum("bhk,hkj->bhj", h, R[g]) for g in range(4)]
        z = jnp.tanh(wxt[:, 0] + rec[0] + bias[0])
        li = wxt[:, 1] + rec[1] + bias[1]
        lf = jax.nn.log_sigmoid(wxt[:, 2] + rec[2] + bias[2])
        o = jax.nn.sigmoid(wxt[:, 3] + rec[3] + bias[3])
        m_new = jnp.maximum(lf + m, li)
        m_new = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        a = jnp.exp(lf + m - m_new)
        bcf = jnp.exp(li - m_new)
        c_new = a * c + bcf * z
        n_new = a * n + bcf
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    (c, n, m, h), hs = jax.lax.scan(step, (c0, n0, m0, h0),
                                    wx.transpose(1, 0, 2, 3, 4))
    y = hs.transpose(1, 0, 2, 3).reshape(B, T, H * hd)
    return y.astype(x.dtype), (c, n, m, h)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _blockdiag_init(key, H, din, dout, pd):
    return L.dense_init(key, (H, din, dout), pd)


def init_mlstm_block(key, cfg):
    d = cfg.d_model
    pf = cfg.xlstm.mlstm_proj_factor
    pdim = int(pf * d)
    H = cfg.num_heads
    phd = pdim // H
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 9)
    return {
        "norm": jnp.zeros((d,), pd),
        "w_up": L.dense_init(ks[0], (d, pdim), pd),
        "w_gate": L.dense_init(ks[1], (d, pdim), pd),
        "conv": init_conv1d(ks[2], pdim, cfg.xlstm.conv1d_width, pd),
        "w_q": _blockdiag_init(ks[3], H, phd, phd, pd),
        "w_k": _blockdiag_init(ks[4], H, phd, phd, pd),
        "w_v": _blockdiag_init(ks[5], H, phd, phd, pd),
        "w_i": L.dense_init(ks[6], (pdim, H), pd),
        "w_f": L.dense_init(ks[7], (pdim, H), pd),
        "b_i": jnp.zeros((H,), pd),
        "b_f": jnp.full((H,), 3.0, pd),          # forget-open init
        "skip": jnp.ones((pdim,), pd),
        "out_norm": jnp.zeros((pdim,), pd),
        "w_down": L.dense_init(ks[8], (pdim, d), pd),
    }


def mlstm_block(bp, cfg, x, state=None, *, chunk: int = 256):
    """state: {'conv': (B,w-1,pdim), 'cell': (C,n,m)} or None."""
    dt = jnp.dtype(cfg.dtype)
    B, T, d = x.shape
    H = cfg.num_heads
    h = L.rms_norm(x, bp["norm"], cfg.norm_eps)
    u = h @ bp["w_up"].astype(dt)                 # (B,T,pdim)
    z = h @ bp["w_gate"].astype(dt)
    conv_state = None if state is None else state["conv"]
    c, new_conv = conv1d_apply(bp["conv"], u, conv_state)
    c = jax.nn.silu(c)
    pdim = u.shape[-1]
    phd = pdim // H
    ch = c.reshape(B, T, H, phd).transpose(0, 2, 1, 3)   # (B,H,T,phd)
    uh = u.reshape(B, T, H, phd).transpose(0, 2, 1, 3)
    q = jnp.einsum("bhtk,hkj->bhtj", ch, bp["w_q"].astype(dt))
    k = jnp.einsum("bhtk,hkj->bhtj", ch, bp["w_k"].astype(dt))
    v = jnp.einsum("bhtk,hkj->bhtj", uh, bp["w_v"].astype(dt))
    cf = c.astype(jnp.float32)
    li = (cf @ bp["w_i"].astype(jnp.float32) + bp["b_i"].astype(jnp.float32))
    lf = jax.nn.log_sigmoid(
        cf @ bp["w_f"].astype(jnp.float32) + bp["b_f"].astype(jnp.float32))
    li = li.transpose(0, 2, 1)                    # (B,H,T)
    lf = lf.transpose(0, 2, 1)
    cell_state = None if state is None else state["cell"]
    if T == 1:
        hcell, new_cell = mlstm_recurrent(q, k, v, li, lf, cell_state)
    else:
        hcell, new_cell = mlstm_chunked(q, k, v, li, lf, cell_state,
                                        chunk=min(chunk, T),
                                        unroll=cfg.unroll_layers)
    hcell = hcell.transpose(0, 2, 1, 3).reshape(B, T, pdim).astype(dt)
    hcell = L.rms_norm(hcell, bp["out_norm"], cfg.norm_eps)
    hcell = hcell + bp["skip"].astype(dt) * c
    y = (hcell * jax.nn.silu(z)) @ bp["w_down"].astype(dt)
    new_state = {"conv": new_conv, "cell": new_cell}
    return x + y, new_state


def init_slstm_block(key, cfg):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    pf = cfg.xlstm.slstm_proj_factor
    fdim = int(pf * d)
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    cell = {
        "w": L.dense_init(ks[0], (d, 4, H, hd), pd),
        "b": jnp.concatenate([
            jnp.zeros((3, H, hd), jnp.float32),
            jnp.zeros((1, H, hd), jnp.float32)], 0).at[2].set(3.0).astype(pd),
        "r_z": _blockdiag_init(ks[1], H, hd, hd, pd),
        "r_i": _blockdiag_init(ks[2], H, hd, hd, pd),
        "r_f": _blockdiag_init(ks[3], H, hd, hd, pd),
        "r_o": _blockdiag_init(ks[4], H, hd, hd, pd),
    }
    return {
        "norm": jnp.zeros((d,), pd),
        "conv": init_conv1d(ks[5], d, cfg.xlstm.conv1d_width, pd),
        "cell": cell,
        "mlp_norm": jnp.zeros((d,), pd),
        "w_ff1": L.dense_init(ks[6], (d, fdim), pd),
        "w_ff2": L.dense_init(ks[7], (fdim, d), pd),
    }


def slstm_block(bp, cfg, x, state=None):
    dt = jnp.dtype(cfg.dtype)
    h = L.rms_norm(x, bp["norm"], cfg.norm_eps)
    conv_state = None if state is None else state["conv"]
    c, new_conv = conv1d_apply(bp["conv"], h, conv_state)
    c = jax.nn.silu(c)
    cell_state = None if state is None else state["cell"]
    y, new_cell = slstm_apply(bp["cell"], c, cell_state)
    x = x + y.astype(dt)
    hh = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
    ff = jax.nn.gelu(hh @ bp["w_ff1"].astype(dt)) @ bp["w_ff2"].astype(dt)
    new_state = {"conv": new_conv, "cell": new_cell}
    return x + ff, new_state


# ---------------------------------------------------------------------------
# full model (heterogeneous stack -> per-layer python loop; xlstm-125m is
# 12 layers so HLO stays small without scan)
# ---------------------------------------------------------------------------


def _layer_kinds(cfg):
    s = set(cfg.xlstm.slstm_at)
    return ["slstm" if i in s else "mlstm" for i in range(cfg.num_layers)]


def init(key, cfg):
    assert cfg.xlstm is not None
    kinds = _layer_kinds(cfg)
    ks = jax.random.split(key, cfg.num_layers + 2)
    pd = jnp.dtype(cfg.param_dtype)
    blocks = tuple(
        init_slstm_block(ks[i], cfg) if kind == "slstm"
        else init_mlstm_block(ks[i], cfg)
        for i, kind in enumerate(kinds))
    return {
        "embed": L.dense_init(ks[-2], (cfg.vocab_size, cfg.d_model), pd,
                              scale=1.0),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), pd),
        "unembed": L.dense_init(ks[-1], (cfg.d_model, cfg.vocab_size), pd),
    }


def init_cache(cfg, batch: int, seq_len: int):
    kinds = _layer_kinds(cfg)
    d = cfg.d_model
    H = cfg.num_heads
    pf = cfg.xlstm.mlstm_proj_factor
    pdim = int(pf * d)
    phd = pdim // H
    hd = d // H
    w = cfg.xlstm.conv1d_width
    states = []
    for kind in kinds:
        if kind == "mlstm":
            states.append({
                "conv": jnp.zeros((batch, w - 1, pdim), jnp.dtype(cfg.dtype)),
                "cell": (jnp.zeros((batch, H, phd, phd), jnp.float32),
                         jnp.zeros((batch, H, phd), jnp.float32),
                         jnp.full((batch, H), -jnp.inf, jnp.float32)),
            })
        else:
            states.append({
                "conv": jnp.zeros((batch, w - 1, d), jnp.dtype(cfg.dtype)),
                "cell": (jnp.zeros((batch, H, hd), jnp.float32),
                         jnp.zeros((batch, H, hd), jnp.float32),
                         jnp.full((batch, H, hd), -jnp.inf, jnp.float32),
                         jnp.zeros((batch, H, hd), jnp.float32)),
            })
    return tuple(states)


def forward(params, cfg, tokens, *, positions=None, caches=None,
            cache_index=None, embeddings=None):
    dt = jnp.dtype(cfg.dtype)
    kinds = _layer_kinds(cfg)
    x = (params["embed"][tokens] if embeddings is None else embeddings
         ).astype(dt)
    new_states = []
    for i, kind in enumerate(kinds):
        bp = params["blocks"][i]
        st = None if caches is None else caches[i]
        fn = slstm_block if kind == "slstm" else mlstm_block
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=(1,))
        x, ns = fn(bp, cfg, x, st)
        new_states.append(ns)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["unembed"].astype(dt)
    new_caches = None if caches is None else tuple(new_states)
    return logits, new_caches, jnp.float32(0.0)
